"""PE instruction set + BLAS/LAPACK instruction-stream compilers.

The paper's experimental setup (section 5, fig. 11) is a scalar Processing
Element whose four floating-point units (multiplier / adder / divider /
square root) have *configurable pipeline depths*, fed by instruction streams
compiled from BLAS and LAPACK routines. This module is that apparatus:

  * a tiny SSA ISA (every instruction's destination is its own index),
  * compilers that lower ddot / dgemv / dgemm / DGEQRF / DGETRF / DPOTRF into
    literal dataflow instruction streams, carrying the *true* dependence
    structure (the matrix is tracked as an SSA id table across updates, so a
    column norm in QR step k really depends on step k-1's trailing update).

The streams are executed by the cycle-level scoreboard in
:mod:`repro.core.pe`.  The symbolic censuses of
:mod:`repro.core.characterization` are testable against these streams
(tests/test_characterization.py).

The "enhanced PE" of section 5 reconfigures 4 multipliers + 3 adders into a
DOT4 instruction; ``dot4=True`` in the GEMM/ddot compilers emits that form.
The LAP-PE baseline [2][5] executes FMACs; ``fma=True`` emits chained FMAs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

# Opcodes. RF-resident operands (preloaded by the APE per the paper's step
# 1-2) appear as src = -1: ready at cycle 0.
NOP, MUL, ADD, DIV, SQRT, FMA, DOT4 = 0, 1, 2, 3, 4, 5, 6
OPCODE_NAMES = {NOP: "nop", MUL: "mul", ADD: "add", DIV: "div", SQRT: "sqrt",
                FMA: "fma", DOT4: "dot4"}
# FLOPs retired per instruction (double precision).
OPCODE_FLOPS = {NOP: 0, MUL: 1, ADD: 1, DIV: 1, SQRT: 1, FMA: 2, DOT4: 7}
# Which depth-configured unit produces the latency of each opcode:
# fma = mul chained into add; dot4 = mul + 2 adder-tree levels.
N_OPCODES = 7


@dataclasses.dataclass
class InstrStream:
    """A compiled instruction stream in SSA form.

    ``opcode[i]`` executes with operands ``src1[i]``/``src2[i]`` (indices of
    earlier instructions, or -1 for RF-resident inputs) and defines value
    ``i``.  In-order single-issue, stall-on-use - exactly the paper's scalar
    PE front end.
    """

    name: str
    opcode: np.ndarray          # int32[N]
    src1: np.ndarray            # int32[N]
    src2: np.ndarray            # int32[N]

    @property
    def n_instructions(self) -> int:
        return int(self.opcode.shape[0])

    @property
    def flops(self) -> int:
        counts = np.bincount(self.opcode, minlength=N_OPCODES)
        return int(sum(OPCODE_FLOPS[op] * int(c) for op, c in enumerate(counts)))

    def census(self) -> Dict[str, int]:
        """Instruction count per paper op class (dot4/fma folded into mul+add)."""
        counts = np.bincount(self.opcode, minlength=N_OPCODES)
        return {
            "mul": int(counts[MUL] + counts[FMA] + 4 * counts[DOT4]),
            "add": int(counts[ADD] + counts[FMA] + 3 * counts[DOT4]),
            "div": int(counts[DIV]),
            "sqrt": int(counts[SQRT]),
        }

    def hazard_census(self, window: int = 1) -> Dict[str, int]:
        """Dependency hazards per class: instructions whose operand is
        produced fewer than ``window`` slots earlier (back-to-back dependences
        that necessarily expose pipe latency on the in-order PE)."""
        idx = np.arange(self.n_instructions)
        near1 = (self.src1 >= 0) & (idx - self.src1 <= window)
        near2 = (self.src2 >= 0) & (idx - self.src2 <= window)
        haz = near1 | near2
        out = {}
        for cls, ops in (("mul", (MUL,)), ("add", (ADD, FMA, DOT4)),
                         ("div", (DIV,)), ("sqrt", (SQRT,))):
            m = np.isin(self.opcode, ops)
            out[cls] = int(np.sum(haz & m))
        return out


class _Builder:
    """Append-only SSA stream builder (list-of-chunks, O(1) amortized)."""

    def __init__(self, name: str):
        self.name = name
        self._op: List[np.ndarray] = []
        self._s1: List[np.ndarray] = []
        self._s2: List[np.ndarray] = []
        self._n = 0

    def emit_block(self, opcode, src1, src2) -> np.ndarray:
        """Emit a vector of instructions; returns their SSA ids."""
        op = np.asarray(opcode, dtype=np.int32)
        s1 = np.asarray(src1, dtype=np.int32)
        s2 = np.asarray(src2, dtype=np.int32)
        op, s1, s2 = np.broadcast_arrays(op, s1, s2)
        ids = np.arange(self._n, self._n + op.size, dtype=np.int32)
        self._op.append(op.ravel().astype(np.int32))
        self._s1.append(s1.ravel().astype(np.int32))
        self._s2.append(s2.ravel().astype(np.int32))
        self._n += op.size
        return ids

    def emit(self, opcode: int, src1: int = -1, src2: int = -1) -> int:
        return int(self.emit_block([opcode], [src1], [src2])[0])

    def tree_reduce(self, ids: np.ndarray, opcode: int = ADD) -> int:
        """Balanced binary reduction; returns the root id."""
        ids = np.asarray(ids, dtype=np.int32)
        while ids.size > 1:
            half = ids.size // 2
            left, right = ids[:half], ids[half:2 * half]
            new = self.emit_block(np.full(half, opcode), left, right)
            ids = np.concatenate([new, ids[2 * half:]])
        return int(ids[0])

    def chain_reduce(self, ids: np.ndarray, opcode: int = ADD) -> int:
        """Sequential accumulation a+=x (the fully serial schedule)."""
        ids = np.asarray(ids, dtype=np.int32)
        acc = int(ids[0])
        for v in ids[1:]:
            acc = self.emit(opcode, acc, int(v))
        return acc

    def strided_reduce(self, ids: np.ndarray, accumulators: int) -> int:
        """U parallel partial sums, round-robin, then a tree combine.

        This is the TPU-codesign schedule: U plays the role of pipeline depth
        p - each partial-sum chain sees a new operand every U issue slots.
        """
        ids = np.asarray(ids, dtype=np.int32)
        u = max(1, min(int(accumulators), ids.size))
        accs = list(ids[:u].astype(int))
        rest = ids[u:]
        # round-robin: emit in interleaved order so chains alternate.
        for start in range(0, rest.size, u):
            block = rest[start:start + u]
            new = self.emit_block(np.full(block.size, ADD),
                                  np.asarray(accs[:block.size]), block)
            accs[:block.size] = list(new)
        return self.tree_reduce(np.asarray(accs, dtype=np.int32))

    def build(self) -> InstrStream:
        if not self._op:
            self.emit(NOP)
        return InstrStream(self.name,
                           np.concatenate(self._op),
                           np.concatenate(self._s1),
                           np.concatenate(self._s2))


# ---------------------------------------------------------------------------
# BLAS compilers (section 4.1 workloads)
# ---------------------------------------------------------------------------

def compile_ddot(n: int, schedule: str = "tree", accumulators: int = 8,
                 dot4: bool = False, fma: bool = False) -> InstrStream:
    """Inner product x.y - n muls (independent) + a reduction (fig. 5)."""
    b = _Builder(f"ddot{n}")
    if dot4:
        ids = b.emit_block(np.full(n // 4, DOT4), -1, -1)
        if n % 4:
            ids = np.append(ids, b.emit_block(np.full(1, DOT4), -1, -1))
        b.strided_reduce(ids, accumulators)
        return b.build()
    if fma:
        # FMAC chain: acc = fma(a_i, b_i, acc) - fully serial (LAP-PE mode).
        acc = b.emit(MUL, -1, -1)
        for _ in range(n - 1):
            acc = b.emit(FMA, -1, acc)
        return b.build()
    muls = b.emit_block(np.full(n, MUL), -1, -1)
    if schedule == "tree":
        b.tree_reduce(muls)
    elif schedule == "sequential":
        b.chain_reduce(muls)
    elif schedule == "strided":
        b.strided_reduce(muls, accumulators)
    else:
        raise ValueError(schedule)
    return b.build()


def compile_dgemv(m: int, n: int, schedule: str = "tree",
                  accumulators: int = 8) -> InstrStream:
    b = _Builder(f"dgemv{m}x{n}")
    for _ in range(m):
        muls = b.emit_block(np.full(n, MUL), -1, -1)
        if schedule == "tree":
            b.tree_reduce(muls)
        elif schedule == "sequential":
            b.chain_reduce(muls)
        else:
            b.strided_reduce(muls, accumulators)
    return b.build()


def compile_dgemm(m: int, n: int, k: int, unroll: int = 4,
                  dot4: bool = False) -> InstrStream:
    """C = A B as m*n length-k inner products, register-blocked by ``unroll``.

    ``unroll`` C elements are kept in flight; their mul/add chains are
    interleaved round-robin, which is precisely the compiler hazard reduction
    the paper cites [23]: each accumulate sees its operand ``unroll`` issue
    slots later.
    """
    b = _Builder(f"dgemm{m}x{n}x{k}")
    cells = m * n
    u = max(1, int(unroll))
    for g0 in range(0, cells, u):
        g = min(u, cells - g0)
        if dot4:
            steps = -(-k // 4)
            accs = np.asarray([b.emit(DOT4, -1, -1) for _ in range(g)])
            for _ in range(steps - 1):
                parts = b.emit_block(np.full(g, DOT4), -1, -1)
                accs = b.emit_block(np.full(g, ADD), accs, parts)
        else:
            accs = b.emit_block(np.full(g, MUL), -1, -1)   # t = 0 products
            for _ in range(1, k):
                parts = b.emit_block(np.full(g, MUL), -1, -1)
                accs = b.emit_block(np.full(g, ADD), accs, parts)
    return b.build()


# ---------------------------------------------------------------------------
# LAPACK compilers (section 4.2 workloads) - full dataflow fidelity: the
# current matrix is an SSA id table, so panel/trailing dependences are real.
# ---------------------------------------------------------------------------

def compile_dgeqrf(n: int, unroll: int = 4) -> InstrStream:
    """Householder QR of n-by-n (DGEQRF): serial sqrt/div on the panel path,
    GEMM-like trailing updates."""
    b = _Builder(f"dgeqrf{n}")
    ids = np.full((n, n), -1, dtype=np.int32)       # SSA id of each A entry
    for kcol in range(n - 1):
        m = n - kcol
        col = ids[kcol:, kcol]
        # ||x||^2: m squares + tree reduce. Depends on current column values.
        sq = b.emit_block(np.full(m, MUL), col, col)
        nrm2 = b.tree_reduce(sq)
        nrm = b.emit(SQRT, nrm2, -1)                 # serial: waits on reduce
        alpha = b.emit(ADD, int(col[0]), nrm)        # x0 + sign*||x||
        # v = x / alpha for the sub-diagonal entries: m-1 divisions, all
        # waiting on alpha (the paper's "always dependency ... that stalls").
        v = b.emit_block(np.full(m - 1, DIV), col[1:], alpha)
        v = np.concatenate([[alpha], v]).astype(np.int32)  # v0 ~ alpha slot
        tau = b.emit(DIV, nrm2, alpha)               # tau = beta path
        # Trailing update per column j > kcol, ``unroll`` columns in flight:
        for j0 in range(kcol + 1, n, unroll):
            cols = list(range(j0, min(j0 + unroll, n)))
            wids = []
            for j in cols:                           # w_j = v . A[:, j]
                prods = b.emit_block(np.full(m, MUL), v, ids[kcol:, j])
                wids.append(b.strided_reduce(prods, unroll))
            for j, w in zip(cols, wids):             # A[:,j] -= tau*v*w_j
                tw = b.emit(MUL, tau, w)
                upd = b.emit_block(np.full(m, MUL), v, tw)
                newc = b.emit_block(np.full(m, ADD), ids[kcol:, j], upd)
                ids[kcol:, j] = newc
    return b.build()


def compile_dgetrf(n: int, unroll: int = 4) -> InstrStream:
    """LU with partial pivoting (DGETRF). Pivot search compares run on the
    adder pipe (FP compare = subtract); column scaling is the serial div
    stream; trailing update is an outer product."""
    b = _Builder(f"dgetrf{n}")
    ids = np.full((n, n), -1, dtype=np.int32)
    for kcol in range(n - 1):
        m = n - kcol
        # pivot search: tree of compares over the column (adder pipe).
        piv = b.tree_reduce(ids[kcol:, kcol], opcode=ADD)
        # scale: l_ik = a_ik / pivot - all m-1 divs wait on the pivot compare.
        l = b.emit_block(np.full(m - 1, DIV), ids[kcol + 1:, kcol], piv)
        ids[kcol + 1:, kcol] = l
        # trailing update, ``unroll`` columns in flight:
        for j0 in range(kcol + 1, n, unroll):
            cols = list(range(j0, min(j0 + unroll, n)))
            for j in cols:
                prods = b.emit_block(np.full(m - 1, MUL), l, ids[kcol, j])
                newc = b.emit_block(np.full(m - 1, ADD), ids[kcol + 1:, j], prods)
                ids[kcol + 1:, j] = newc
    return b.build()


def compile_dpotrf(n: int, unroll: int = 4) -> InstrStream:
    """Cholesky (DPOTRF, lower): serial sqrt on the diagonal, divs per column."""
    b = _Builder(f"dpotrf{n}")
    ids = np.full((n, n), -1, dtype=np.int32)
    for kcol in range(n):
        d = b.emit(SQRT, ids[kcol, kcol], -1)
        ids[kcol, kcol] = d
        m = n - kcol - 1
        if m == 0:
            continue
        l = b.emit_block(np.full(m, DIV), ids[kcol + 1:, kcol], d)
        ids[kcol + 1:, kcol] = l
        for j in range(kcol + 1, n):                 # rank-1 trailing update
            rows = np.arange(j, n)
            prods = b.emit_block(np.full(rows.size, MUL), ids[j, kcol],
                                 ids[rows, kcol])
            newc = b.emit_block(np.full(rows.size, ADD), ids[rows, j], prods)
            ids[rows, j] = newc
    return b.build()


COMPILERS = {
    "ddot": compile_ddot,
    "dgemv": compile_dgemv,
    "dgemm": compile_dgemm,
    "dgeqrf": compile_dgeqrf,
    "dgetrf": compile_dgetrf,
    "dpotrf": compile_dpotrf,
}
