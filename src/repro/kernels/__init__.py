"""Pallas TPU kernels for the paper's compute hot spots.

gemm (MXU DOT4 generalization), dotp (codesigned level-1 reduce),
flash_attention (streaming softmax), ssd_scan (Mamba-2 chunked scan),
fused (FBLAS-style streaming stage chains: gemm_bias_act, trsm_gemm).
Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatching API.
"""
from repro.kernels import fused, ops, ref
