"""Public jit'd wrappers over the Pallas kernels, with oracle dispatch.

Every op takes ``use_pallas`` (default True on TPU backends, False
elsewhere) so model code calls one API and gets: the Pallas kernel on TPU,
``interpret=True`` Pallas in kernel tests, and the pure-jnp oracle inside
the distributed CPU lowering path (where interpret-mode pallas_call cannot
be partitioned).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dotp as _dotp
from repro.kernels import flash_attention as _fa
from repro.kernels import gemm as _gemm
from repro.kernels import ref
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gemm(a, b, plan=None, use_pallas: Optional[bool] = None,
         interpret: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.gemm(a, b)
    return _gemm.gemm(a, b, plan=plan,
                      interpret=not _on_tpu() if interpret is None else interpret)


def dotp(x, y, accumulators=None, use_pallas: Optional[bool] = None,
         interpret: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.dotp(x, y)
    return _dotp.dotp(x, y, accumulators=accumulators,
                      interpret=not _on_tpu() if interpret is None else interpret)


BLOCKED_ATTN_THRESHOLD = 2048


def attention(q, k, v, causal: bool = True, scale=None, q_offset: int = 0,
              window=None, kv_len=None, use_pallas: Optional[bool] = None,
              interpret: Optional[bool] = None, **block_kw):
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        if (window is not None and causal and q_offset == 0
                and q.shape[2] == k.shape[2]
                and k.shape[2] >= 4 * window):
            # banded path: O(S*2w) flops/bytes instead of O(S^2)
            return ref.banded_attention(q, k, v, window, scale=scale)
        if k.shape[2] >= BLOCKED_ATTN_THRESHOLD:
            # streaming path: O(S*block) memory, SPMD-partitionable
            return ref.blocked_attention(q, k, v, causal=causal, scale=scale,
                                         q_offset=q_offset, window=window)
        return ref.attention(q, k, v, causal=causal, scale=scale,
                             q_offset=q_offset, window=window)
    return _fa.attention(q, k, v, causal=causal, scale=scale,
                         q_offset=q_offset, window=window, kv_len=kv_len,
                         interpret=not _on_tpu() if interpret is None else interpret,
                         **block_kw)


def ssd(x, a_log, B, C, chunk=None, use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None):
    """SSD in model layout: x (B, L, H, P), a_log (B, L, H), B/C (B, L, H, N).
    Returns y (B, L, H, P)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ssd_chunked(x, a_log, B, C, chunk=chunk or 64)
    xt = jnp.moveaxis(x, 2, 1)             # (B,H,L,P)
    at = jnp.moveaxis(a_log, 2, 1)         # (B,H,L)
    Bt = jnp.moveaxis(B, 2, 1)
    Ct = jnp.moveaxis(C, 2, 1)
    y = _ssd.ssd_scan(xt, at, Bt, Ct, chunk=chunk,
                      interpret=not _on_tpu() if interpret is None else interpret)
    return jnp.moveaxis(y, 1, 2)
