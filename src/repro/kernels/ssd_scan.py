"""Pallas Mamba-2 SSD chunked scan.

The state-space recurrence h_t = a_t h_{t-1} + x_t (x) B_t is the paper's
serial hazard chain in its purest form: every step depends on the last. The
SSD (state-space duality) chunking is exactly the paper's remedy applied at
algorithm level - convert most of the chain into parallel within-chunk work
(a masked-decay "attention" matrix on the MXU) and keep only one serial
dependence per chunk. Chunk size from :func:`repro.core.codesign.plan_ssd`
balances the c^2 within-chunk term against the seq/c serial chain - the
busy/non-busy split of eq. 1.

Layout (pre-arranged by ops.ssd): x (B, H, L, P), a_log (B, H, L),
B/C (B, H, L, N). Grid (B, H, L/c), chunk dim sequential; fp32 (P, N) state
carried in VMEM scratch across chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codesign import plan_ssd
from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    al = a_ref[0, 0].astype(jnp.float32)                 # (c,)
    x = x_ref[0, 0].astype(jnp.float32)                  # (c, P)
    Bm = b_ref[0, 0].astype(jnp.float32)                 # (c, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                 # (c, N)
    cum = jnp.cumsum(al)                                 # (c,)
    seg = jnp.exp(cum)                                   # decay since entry
    t_io = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_io = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask before exp (upper-triangle diffs are positive -> overflow)
    diff = cum[:, None] - cum[None, :]
    Lmat = jnp.exp(jnp.where(t_io >= s_io, diff, -jnp.inf))
    # within-chunk (parallel, MXU): masked-decay attention
    scores = lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * Lmat
    y = lax.dot(scores, x, preferred_element_type=jnp.float32)   # (c, P)
    # cross-chunk (the one serial hazard): contribution of carried state
    state = state_ref[...]                               # (P, N)
    y = y + lax.dot_general(Cm * seg[:, None], state,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    dout = jnp.exp(cum[-1] - cum)                        # (c,)
    state_ref[...] = (jnp.exp(cum[-1]) * state
                      + lax.dot_general(x, Bm * dout[:, None],
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x: jnp.ndarray, a_log: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, chunk: int | None = None,
             interpret: bool = True) -> jnp.ndarray:
    """Chunked SSD over (B, H, L, ...) layout; returns y (B, H, L, P)."""
    if 0 in x.shape or 0 in a_log.shape or 0 in B.shape or 0 in C.shape:
        # zero-dim operands cannot tile a Pallas grid (rule KL004): empty
        # batch/head/length/feature axes make y empty, and an empty state
        # axis N zeroes every contribution - jnp zeros of x's shape is
        # the exact answer either way
        return jnp.zeros(x.shape, x.dtype)
    bsz, h, L, p = x.shape
    n = B.shape[-1]
    if chunk is None:
        chunk = plan_ssd(L, h, p, n).chunk
    chunk = min(chunk, max(L, 8))
    pad = (-L) % chunk
    if pad:  # a_log pads with 0 (decay 1): state passes through untouched
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // chunk
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, i: (b_, h_, i)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, i: (b_, h_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, L + pad, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a_log, B, C)
    return y[:, :, :L]
