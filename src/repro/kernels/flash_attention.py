"""Pallas streaming-softmax attention (prefill + decode).

Attention's inner loop is the paper's dependent-reduction pattern at scale:
the online-softmax running triple (m, l, acc) is a serial chain across KV
blocks - a hazard per block - while everything inside a block is parallel.
Block sizes come from :func:`repro.core.codesign.plan_attention`: bigger
``block_k`` means fewer serial rescales (fewer hazards) at higher VMEM cost,
the exact eq.-2 trade-off.

Layout: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D), GQA via Hq = g * Hkv.
Grid (B, Hq, Sq/bq, Sk/bk), KV innermost (sequential) so the fp32 running
state lives in VMEM scratch across KV steps.

Supports causal masking with an absolute ``q_offset`` (decode: Sk - Sq),
sliding windows, and KV-length masking for padded caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codesign import LANE, plan_attention
from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, q_offset: int, kv_len: int,
                 window: Optional[int], block_q: int, block_k: int,
                 nk: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(2)
    qpos = (i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            + q_offset)
    kpos = (kk * block_k
            + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kk == nk - 1)
    def _flush():
        l = l_ref[:, :1]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, scale: Optional[float] = None,
              q_offset: int = 0, window: Optional[int] = None,
              kv_len: Optional[int] = None,
              block_q: Optional[int] = None, block_k: Optional[int] = None,
              interpret: bool = True) -> jnp.ndarray:
    """Flash attention; see module docstring for layout. Returns q-shaped."""
    if 0 in q.shape or 0 in k.shape or 0 in v.shape:
        # zero-dim operands cannot tile a Pallas grid (rule KL004): an
        # empty batch/head/query/feature axis makes the output empty, and
        # an empty KV axis leaves every denominator at the kernel's
        # safe-divide zero - jnp zeros of q's shape is exact either way
        return jnp.zeros(q.shape, q.dtype)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else sk
    plan = plan_attention(sq, sk, d)
    bq = block_q or min(plan.block_q, max(8, sq))
    bk = block_k or min(plan.block_k, max(LANE, sk))
    bq = max(8, min(bq, -(-sq // 8) * 8))
    pq, pk_ = (-(-sq // bq) * bq, -(-sk // bk) * bk)
    if pq != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq - sq), (0, 0)))
    if pk_ != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk_ - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk_ - sk), (0, 0)))
    nk = pk_ // bk
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, kv_len=kv_len, window=window,
                          block_q=bq, block_k=bk, nk=nk),
        grid=(b, hq, pq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, kk: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, kk: (b_, h // group, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, kk: (b_, h // group, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, kk: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANE), jnp.float32),   # running max m
            pltpu.VMEM((bq, LANE), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
