"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are sweep-tested
against (tests/test_kernels_*.py). They are also the implementations the
distributed model path uses on this CPU container (kernels are per-shard
drop-ins on real TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.gemm import accumulator_dtype
    pet = accumulator_dtype(a.dtype)   # f64 accumulates in f64, rest in f32
    return jnp.dot(a, b, preferred_element_type=pet).astype(a.dtype)


def dotp(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, scale: float | None = None,
              q_offset: int = 0, window: int | None = None) -> jnp.ndarray:
    """Multi-head attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq a multiple of Hkv (GQA).
    ``q_offset``: absolute position of q[0] (decode: Sk - Sq).
    ``window``: sliding-window size (None = full).
    Returns (B, Hq, Sq, D) in q.dtype; softmax in fp32.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, group, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    sk = k.shape[2]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows that are fully masked produce NaN; zero them (can't happen for
    # causal decode, defensive for window edges)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, scale: float | None = None,
                      q_offset: int = 0, window: int | None = None,
                      block_k: int = 1024) -> jnp.ndarray:
    """Streaming-softmax attention in pure jnp (lax.scan over KV blocks).

    Same semantics as :func:`attention` but O(Sq * block_k) live memory
    instead of O(Sq * Sk): this is the partitionable flash path the SPMD
    lowering uses (pallas_call cannot be auto-partitioned by XLA; on real
    TPU the Pallas kernel drops in per-shard under shard_map). The KV-block
    scan body is checkpointed so the backward pass recomputes block scores
    instead of saving them - flash semantics under autodiff.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = jnp.moveaxis(k.reshape(b, hkv, nb, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nb, block_k, d), 2, 0)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d) * scale
    qpos = jnp.arange(sq) + q_offset

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, i = blk
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc.astype(jnp.float32))
        kpos = i * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < sk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((b, hkv, g, sq), -1e30, jnp.float32),
            jnp.zeros((b, hkv, g, sq), jnp.float32),
            jnp.zeros((b, hkv, g, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), init,
        (kb, vb, jnp.arange(nb)))
    safe = jnp.where(l > 0, l, 1.0)
    out = (acc / safe[..., None]).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def banded_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int, scale: float | None = None,
                     q_offset: int = 0) -> jnp.ndarray:
    """Causal sliding-window attention in O(S * 2w) instead of O(S^2).

    Block the sequence into window-sized tiles; a query in tile i can only
    attend keys in tiles i-1 and i (positions differ by < window <= tile).
    Exact - verified against the masked full-attention oracle. This is the
    hymba-prefill hillclimb: at S=32k, w=1k it removes 15/16 of the
    attention flops and the whole S x S traffic.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert sq == sk and q_offset == 0, "banded path is for full-seq prefill"
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    w = window
    pad = (-sq) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = sq + pad
    nb = sp // w
    qb = q.reshape(b, hkv, g, nb, w, d).astype(jnp.float32) * scale
    kb = k.reshape(b, hkv, nb, w, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nb, w, d).astype(jnp.float32)
    # previous tile (zeros before tile 0)
    kprev = jnp.pad(kb, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :nb]
    vprev = jnp.pad(vb, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :nb]
    kcat = jnp.concatenate([kprev, kb], axis=3)          # (b,hkv,nb,2w,d)
    vcat = jnp.concatenate([vprev, vb], axis=3)
    s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qb, kcat)     # (b,hkv,g,nb,w,2w)
    qpos = jnp.arange(w)[:, None] + w                    # within [w, 2w)
    kpos = jnp.arange(2 * w)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < w)
    first = jnp.arange(2 * w)[None, :] >= w              # tile 0: no prev
    m0 = mask & first
    tile_idx = jnp.arange(nb)
    full_mask = jnp.where(tile_idx[:, None, None] == 0, m0[None], mask[None])
    s = jnp.where(full_mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", p, vcat)
    o = o.reshape(b, hq, sp, d)[:, :, :sq]
    return o.astype(q.dtype)


def ssd(x: jnp.ndarray, a_log: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
        state: jnp.ndarray | None = None, return_state: bool = False):
    """Mamba-2 SSD oracle: the exact O(L) recurrence.

    x:     (batch, L, H, P)   inputs (already gated/dt-scaled)
    a_log: (batch, L, H)      log decay per step (<= 0)
    B:     (batch, L, H, N)   input projection (already per-head)
    C:     (batch, L, H, N)   output projection (already per-head)
    state: (batch, H, P, N)   optional initial state

    h_t = exp(a_log_t) * h_{t-1} + x_t outer B_t;   y_t = h_t @ C_t
    Returns y (batch, L, H, P) [and final state if requested].
    """
    bsz, L, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    af = a_log.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    h0 = (jnp.zeros((bsz, H, P, N), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(h, t):
        a_t = jnp.exp(af[:, t])[..., None, None]          # (b,H,1,1)
        upd = jnp.einsum("bhp,bhn->bhpn", xf[:, t], Bf[:, t])
        h = a_t * h + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Cf[:, t])
        return h, y

    hT, ys = jax.lax.scan(step, h0, jnp.arange(L))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)            # (b,L,H,P)
    if return_state:
        return y, hT.astype(jnp.float32)
    return y


def ssd_chunked(x, a_log, B, C, chunk: int = 64, state=None,
                return_state: bool = False):
    """Chunked SSD (the algorithm the Pallas kernel implements): quadratic
    within-chunk attention-like term + cross-chunk state recurrence.
    Mathematically identical to :func:`ssd`."""
    bsz, L, H, P = x.shape
    N = B.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nch = Lp // chunk

    def to_chunks(t):  # (b, L, H, ...) -> (nch, b, H, chunk, ...)
        t = t.reshape(bsz, nch, chunk, *t.shape[2:])
        return jnp.moveaxis(jnp.moveaxis(t, 3, 2), 1, 0).astype(jnp.float32)

    xc, ac = to_chunks(x), to_chunks(a_log)
    Bc, Cc = to_chunks(B), to_chunks(C)
    h0 = (jnp.zeros((bsz, H, P, N), jnp.float32) if state is None
          else state.astype(jnp.float32))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(h, inp):
        xk, ak, Bk, Ck = inp                               # (b,H,c,...)
        cum = jnp.cumsum(ak, axis=-1)                      # (b,H,c)
        seg = jnp.exp(cum)                                 # state decay at t
        # mask BEFORE exp: exp of the (positive) upper-triangle differences
        # overflows and poisons the backward pass with inf * 0 = NaN
        diff = cum[..., :, None] - cum[..., None, :]
        Lmat = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        scores = jnp.einsum("bhtn,bhsn->bhts", Ck, Bk) * Lmat
        y = jnp.einsum("bhts,bhsp->bhtp", scores, xk)
        y = y + jnp.einsum("bhtn,bhpn->bhtp", Ck * seg[..., None], h)
        dout = jnp.exp(cum[..., -1:] - cum)                # (b,H,c)
        h = (jnp.exp(cum[..., -1])[..., None, None] * h
             + jnp.einsum("bhsp,bhsn->bhpn", xk, Bk * dout[..., None]))
        return h, y

    hT, ys = jax.lax.scan(chunk_step, h0, (xc, ac, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 2).reshape(bsz, H, Lp, P)
    y = jnp.moveaxis(y, 1, 2)[:, :L].astype(x.dtype)
    if return_state:
        return y, hT
    return y


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
