"""Version compatibility for Pallas TPU APIs.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` before jax 0.5;
every kernel imports the alias from here so the package loads on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
