"""Pallas TPU GEMM - the MXU realization of the paper's DOT4 idea.

The paper reconfigures 4 multipliers + 3 adders into a fused multiply-reduce
(DOT4). The MXU *is* that structure scaled to a 128x128 systolic array; this
kernel expresses C = A B as MXU-tile FMAs with a per-precision VMEM
accumulator (fp32 for float32/bfloat16 operands, fp64 for float64), and
takes its tiling from :func:`repro.core.codesign.plan_gemm` - block shapes
are the pipeline-depth analogue (HBM->VMEM grid pipelining; see DESIGN.md
section 2).

Grid: (M/bm, N/bn, K/bk) with the K dimension innermost ('arbitrary'
semantics - sequential), so the accumulator scratch carries across K steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codesign import GemmPlan, plan_gemm
from repro.kernels.compat import CompilerParams


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_dtype)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def accumulator_dtype(dtype) -> jnp.dtype:
    """Per-precision accumulator width (the paper's per-pipeline depths):
    float64 operands accumulate in float64, everything narrower (float32,
    bfloat16) in float32."""
    return jnp.dtype(jnp.float64) if jnp.dtype(dtype) == jnp.float64 \
        else jnp.dtype(jnp.float32)


def gemm(a: jnp.ndarray, b: jnp.ndarray, plan: Optional[GemmPlan] = None,
         out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """C = A @ B via the Pallas MXU kernel.

    Shapes are padded up to block multiples (model-chosen blocks are MXU
    aligned); padding contributes zeros to the accumulation.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    if plan is None:
        plan = plan_gemm(m, n, k, dtype_bytes=a.dtype.itemsize)
    bm, bn, bk = plan.bm, plan.bn, plan.bk
    pm, pn, pk = (-(-d // blk) * blk for d, blk in ((m, bm), (n, bn), (k, bk)))
    a_p = jnp.pad(a, ((0, pm - m), (0, pk - k))) if (pm, pk) != (m, k) else a
    b_p = jnp.pad(b, ((0, pk - k), (0, pn - n))) if (pk, pn) != (k, n) else b
    nk = pk // bk
    acc_dtype = accumulator_dtype(a.dtype)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk, acc_dtype=acc_dtype),
        grid=(pm // bm, pn // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
