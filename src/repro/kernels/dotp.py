"""Pallas level-1 fused multiply-reduce - the paper's ddot, codesigned.

This is the kernel where the paper's analysis is most literal. A dot product
is n independent multiplies feeding a reduction whose *schedule* decides the
adder-pipe hazards (section 4.1, fig. 5). On the TPU VPU, a single running
sum exposes the FP-add latency on every element; U parallel partial
accumulators fill the latency window exactly like U pipeline slots
(DESIGN.md section 2, row 1).

The kernel keeps a (U, 128) fp32 accumulator tile in VMEM; each grid step
streams a (U, 128)-shaped chunk of x*y into it elementwise (one VPU FMA per
lane - 128*U independent chains). The final combine (sum over the tile) is
the paper's small post-loop reduction tree. U comes from
``codesign.optimal_accumulators`` - eq. 3 applied to the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codesign import LANE, optimal_accumulators
from repro.kernels.compat import CompilerParams


def _dotp_kernel(x_ref, y_ref, o_ref, acc_ref, *, nsteps: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += (x_ref[...].astype(jnp.float32)
                     * y_ref[...].astype(jnp.float32))

    @pl.when(i == nsteps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def dotp(x: jnp.ndarray, y: jnp.ndarray, accumulators: Optional[int] = None,
         interpret: bool = True) -> jnp.ndarray:
    """<x, y> with a U-accumulator streaming schedule; returns fp32 scalar."""
    assert x.shape == y.shape and x.ndim == 1
    n = x.shape[0]
    u = accumulators or optimal_accumulators(n)
    width = u * LANE
    pad = (-n) % width
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    nsteps = (n + pad) // width
    xs = x.reshape(nsteps, u, LANE)
    ys = y.reshape(nsteps, u, LANE)
    partials = pl.pallas_call(
        functools.partial(_dotp_kernel, nsteps=nsteps),
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((1, u, LANE), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, u, LANE), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, u, LANE), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, u, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, u, LANE), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xs, ys)
    # the paper's final combine tree over the U*LANE partials
    return jnp.sum(partials)
