"""Streaming Pallas kernel chains - FBLAS-style epilogue/stage fusion.

FBLAS (1907.07929) composes BLAS stages as streams so intermediates never
round-trip through off-chip memory; the paper's PE wins rest on the same
locality (keep the fused multiply-reduce pipeline fed from local storage).
This module is that idea on the Pallas path:

``gemm_bias_act``
    C = act(A B + bias) in one kernel: the epilogue runs on the VMEM
    accumulator block at flush time, so C is written to HBM exactly once
    (the staged path writes A B, then re-reads and re-writes it).

``trsm_gemm``
    The blocked factorizations' trailing pair as one kernel: the panel
    solve X = L11^{-1} AP lands in a VMEM scratch and the trailing GEMM
    row-blocks consume it from there - X reaches HBM only as an output,
    never as a GEMM input. ``form="lu"`` computes C - B X (getrf),
    ``form="syrk"`` computes C - X^T X (potrf).

Whether fusing pays is priced by
:func:`repro.core.codesign.plan_fused_chain` and decided by
:func:`repro.tune.dispatch.resolve` under the ``"gemm+epilogue"`` /
``"trsm+gemm"`` ops; fused launches are annotated with the modeled
``hbm_bytes_saved`` via :func:`fused_span` so traces show the streaming
win. Differential oracle: ``tests/test_fusion.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs as _obs
from repro.core.codesign import GemmPlan, plan_fused_chain, plan_gemm
from repro.kernels.compat import CompilerParams
from repro.kernels.gemm import accumulator_dtype

EPILOGUES = ("none", "relu", "gelu")


def apply_epilogue(x, epilogue: str, bias=None):
    """The one shared epilogue definition: bias add (broadcast over rows),
    then the activation. Used inside the fused kernel, by the staged
    kernel chain, and by the jnp reference path, so all three agree up to
    accumulation order."""
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; "
                         f"expected one of {EPILOGUES}")
    if bias is not None:
        x = x + bias
    if epilogue == "relu":
        x = jnp.maximum(x, jnp.zeros_like(x))
    elif epilogue == "gelu":
        x = jax.nn.gelu(x, approximate=True)
    return x


def fused_span(name: str, chain, **attrs):
    """An obs span for one fused launch, carrying the chain plan's saved
    HBM bytes (the quantity the streaming composition exists to delete)."""
    return _obs.span("fused." + name, cat="fused",
                     hbm_bytes_saved=chain.hbm_bytes_saved,
                     fused_hbm_bytes=chain.fused_hbm_bytes,
                     unfused_hbm_bytes=chain.unfused_hbm_bytes, **attrs)


# ------------------------------ gemm + epilogue ------------------------------

def _gemm_epilogue_kernel(*refs, nk: int, acc_dtype, epilogue: str,
                          has_bias: bool):
    if has_bias:
        a_ref, b_ref, bias_ref, o_ref, acc_ref = refs
    else:
        a_ref, b_ref, o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_dtype)

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        bias = bias_ref[...].astype(acc_dtype) if has_bias else None
        o_ref[...] = apply_epilogue(acc, epilogue, bias).astype(o_ref.dtype)


def gemm_bias_act(a: jnp.ndarray, b: jnp.ndarray,
                  bias: Optional[jnp.ndarray] = None,
                  epilogue: str = "none", plan: Optional[GemmPlan] = None,
                  out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """C = act(A @ B + bias) in one Pallas launch.

    Same grid/tiling contract as :func:`repro.kernels.gemm.gemm` (the
    epilogue costs no extra HBM traffic beyond the optional bias stream);
    ``bias`` is a length-n vector broadcast over rows, applied in the
    accumulator dtype at flush time.
    """
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; "
                         f"expected one of {EPILOGUES}")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    if plan is None:
        plan = plan_gemm(m, n, k, dtype_bytes=a.dtype.itemsize)
    bm, bn, bk = plan.bm, plan.bn, plan.bk
    pm, pn, pk = (-(-d // blk) * blk for d, blk in ((m, bm), (n, bn), (k, bk)))
    a_p = jnp.pad(a, ((0, pm - m), (0, pk - k))) if (pm, pk) != (m, k) else a
    b_p = jnp.pad(b, ((0, pk - k), (0, pn - n))) if (pk, pn) != (k, n) else b
    nk = pk // bk
    acc_dtype = accumulator_dtype(a.dtype)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [a_p, b_p]
    if has_bias:
        bias_p = jnp.pad(jnp.asarray(bias).reshape(1, -1),
                         ((0, 0), (0, pn - n)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bias_p)
    out = pl.pallas_call(
        functools.partial(_gemm_epilogue_kernel, nk=nk, acc_dtype=acc_dtype,
                          epilogue=epilogue, has_bias=has_bias),
        grid=(pm // bm, pn // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


# -------------------------------- trsm -> gemm -------------------------------

def _trsm_gemm_kernel(*refs, form: str, unit_diag: bool, pnb: int,
                      bm: int, acc_dtype):
    if form == "lu":
        l_ref, ap_ref, bl_ref, c_ref, x_ref, o_ref, xs_ref = refs
    else:
        l_ref, ap_ref, c_ref, x_ref, o_ref, xs_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _solve():
        # forward substitution on values (not refs): row r of X depends on
        # rows < r, extracted with one-hot reductions so the loop carries a
        # dense (pnb, n) accumulator - the serial divider chain of
        # level3._trsm_unblocked, run once in VMEM at accumulator width.
        l = l_ref[...].astype(acc_dtype)
        ap = ap_ref[...].astype(acc_dtype)
        ii = lax.broadcasted_iota(jnp.int32, l.shape, 0)
        jj = lax.broadcasted_iota(jnp.int32, l.shape, 1)
        strict = jnp.where(jj < ii, l, jnp.zeros_like(l))
        dvec = jnp.sum(jnp.where(ii == jj, l, jnp.zeros_like(l)), axis=1)
        rows = lax.broadcasted_iota(jnp.int32, ap.shape, 0)

        def body(r, x):
            row_mask = (rows == r)                      # one-hot row of AP
            rhs = jnp.sum(jnp.where(row_mask, ap, jnp.zeros_like(ap)),
                          axis=0)
            lrow = jnp.sum(jnp.where(ii == r, strict,
                                     jnp.zeros_like(strict)), axis=0)
            s = rhs - lrow @ x
            if not unit_diag:
                dk = jnp.sum(jnp.where(jnp.arange(pnb) == r, dvec,
                                       jnp.zeros_like(dvec)))
                s = s / dk
            return x + row_mask.astype(acc_dtype) * s[None, :]

        x = lax.fori_loop(0, pnb, body, jnp.zeros(ap.shape, acc_dtype))
        xs_ref[...] = x
        x_ref[...] = x.astype(x_ref.dtype)

    x = xs_ref[...]
    if form == "lu":
        upd = jnp.dot(bl_ref[...].astype(acc_dtype), x,
                      preferred_element_type=acc_dtype)
    else:
        # index dtypes must match even under x64 (program_id is int32)
        col0 = (i * bm).astype(jnp.int32)
        xi = lax.dynamic_slice(x, (jnp.int32(0), col0), (pnb, bm))
        upd = jnp.dot(xi.T, x, preferred_element_type=acc_dtype)
    o_ref[...] = (c_ref[...].astype(acc_dtype) - upd).astype(o_ref.dtype)


def trsm_gemm(l11: jnp.ndarray, a_panel: jnp.ndarray,
              b_left: Optional[jnp.ndarray], c: jnp.ndarray,
              form: str = "lu", unit_diag: bool = False,
              row_block: Optional[int] = None,
              interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused X = L11^{-1} AP then C -= (B X | X^T X), one Pallas launch.

    Parameters
    ----------
    l11 : (nb, nb) lower-triangular panel diagonal.
    a_panel : (nb, n) right-hand sides AP.
    b_left : (m, nb) left GEMM operand for ``form="lu"``; ``None`` (and
        m == n) for ``form="syrk"``, which reuses X as both operands.
    c : (m, n) trailing block to update.
    row_block : row-block height of the GEMM stage (the chain plan's
        ``block``); the solve itself is not tiled - X stays resident.

    Returns
    -------
    (x, c_out) : the panel solve (nb, n) and the updated trailing block -
    the two arrays the blocked driver writes back.

    Notes
    -----
    The grid is 1-D over C's row blocks with ``arbitrary`` semantics: step
    0 runs the substitution scan into a VMEM scratch, every step reads X
    from that scratch, so X never transits HBM between the stages.
    Padding: nb rows pad with an identity diagonal (solved rows of the
    padding are zero), n columns pad with zeros.
    """
    if form not in ("lu", "syrk"):
        raise ValueError(f"unknown trsm+gemm form {form!r}; "
                         f"expected 'lu' or 'syrk'")
    nb = l11.shape[0]
    n = a_panel.shape[1]
    m = c.shape[0]
    assert a_panel.shape[0] == nb and c.shape[1] == n
    if form == "syrk":
        assert b_left is None and m == n, (m, n)
    else:
        assert b_left is not None and b_left.shape == (m, nb)
    dtype = c.dtype
    acc_dtype = accumulator_dtype(dtype)
    pnb = -(-nb // 8) * 8
    pn = -(-n // 128) * 128
    if form == "syrk":
        # row blocks must tile the padded (pn, pn) output
        bm = row_block if row_block and pn % row_block == 0 else 128
        pm = pn
    else:
        bm = min(row_block or 128, -(-m // 8) * 8)
        pm = -(-m // bm) * bm
    l_p = jnp.pad(l11, ((0, pnb - nb), (0, pnb - nb)))
    if pnb != nb:
        # unit diagonal on the padding keeps the padded solve rows zero
        # (and the division NaN-free)
        l_p = l_p + jnp.diag((jnp.arange(pnb) >= nb).astype(dtype))
    ap_p = jnp.pad(a_panel, ((0, pnb - nb), (0, pn - n)))
    c_p = jnp.pad(c, ((0, pm - m), (0, pn - n)))
    in_specs = [
        pl.BlockSpec((pnb, pnb), lambda i: (0, 0)),
        pl.BlockSpec((pnb, pn), lambda i: (0, 0)),
    ]
    operands = [l_p, ap_p]
    if form == "lu":
        in_specs.append(pl.BlockSpec((bm, pnb), lambda i: (i, 0)))
        operands.append(jnp.pad(b_left, ((0, pm - m), (0, pnb - nb))))
    in_specs.append(pl.BlockSpec((bm, pn), lambda i: (i, 0)))
    operands.append(c_p)
    x_out, c_out = pl.pallas_call(
        functools.partial(_trsm_gemm_kernel, form=form, unit_diag=unit_diag,
                          pnb=pnb, bm=bm, acc_dtype=acc_dtype),
        grid=(pm // bm,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((pnb, pn), lambda i: (0, 0)),
            pl.BlockSpec((bm, pn), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pnb, pn), dtype),
            jax.ShapeDtypeStruct((pm, pn), dtype),
        ],
        scratch_shapes=[pltpu.VMEM((pnb, pn), acc_dtype)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)
    return x_out[:nb, :n], c_out[:m, :n]
