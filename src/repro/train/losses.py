"""Losses: causal-LM cross entropy (fp32, z-loss regularized)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None,
                  z_loss: float = 1e-4):
    """Mean token cross-entropy. logits (B, S, V) any dtype; labels (B, S).

    z-loss (PaLM) keeps the softmax normalizer bounded - at 512-chip scale
    that is a stability feature, not a nicety.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def next_token_loss(logits, tokens, z_loss: float = 1e-4):
    """Shifted LM loss: predict tokens[t+1] from logits[t]. Handles logits
    longer than tokens (prefix embeddings prepended): the prefix positions
    are dropped before shifting."""
    extra = logits.shape[1] - tokens.shape[1]
    if extra:
        logits = logits[:, extra:]
    return cross_entropy(logits[:, :-1], tokens[:, 1:], z_loss=z_loss)
