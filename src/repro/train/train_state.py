"""Train state + the train_step builder used by the launcher and dry-run.

The step supports gradient accumulation (``accum_steps`` microbatches via
lax.scan - activation memory divides by the accumulation factor, and XLA's
latency-hiding scheduler overlaps each microbatch's gradient reduce-scatter
with the next microbatch's compute), global-norm clipping, and the 8-bit
AdamW. Params are stored fp32 (masters) and cast to cfg.dtype inside the
forward; grads accumulate in fp32.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.train import optimizer
from repro.train.losses import next_token_loss
from repro.train.optimizer import AdamWConfig


def init_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig):
    params = model_zoo.init(key, cfg)
    return {"params": params, "opt": optimizer.init(params, opt_cfg)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    shard_fn=lambda x, n: x,
                    donate: bool = True) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch['tokens']``: (accum, B/accum, S) when accum_steps > 1 else (B, S)
    - the launcher reshapes; microbatches scan sequentially.
    """
    accum = max(cfg.accum_steps, 1)

    def loss_fn(params, micro):
        logits, aux = model_zoo.forward(params, micro, cfg, shard_fn=shard_fn,
                                        use_pallas=False)
        return next_token_loss(logits, micro["tokens"]) + aux

    def train_step(state, batch):
        params = state["params"]

        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro_step(acc, micro):
                g_acc, l_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(
                micro_step, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum

        new_params, new_opt, stats = optimizer.update(
            grads, state["opt"], params, opt_cfg)
        metrics = {"loss": loss, **stats}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, shard_fn=lambda x, n: x) -> Callable:
    def eval_step(state, batch):
        logits, aux = model_zoo.forward(state["params"], batch, cfg,
                                        shard_fn=shard_fn, use_pallas=False)
        return {"loss": next_token_loss(logits, batch["tokens"]) + aux}
    return eval_step
