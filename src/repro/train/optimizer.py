"""AdamW from scratch, with optional 8-bit (blockwise-quantized) moments.

The 8-bit moments follow the bitsandbytes recipe: dynamic blockwise
quantization with one fp32 absmax scale per 256-value block. For the 1T-param
assigned arch this is the difference between fitting and not fitting HBM
(EXPERIMENTS.md records the memory_analysis deltas).

All state is a plain pytree so the distributed layer shards it with the same
rules as the parameters (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Q_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    eight_bit: bool = False
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# ---------------------------------------------------------------------------
# blockwise int8 quantization
# ---------------------------------------------------------------------------

def _q8(x: jnp.ndarray):
    """fp32 -> (int8 codes, fp32 block scales). Pads to Q_BLOCK internally."""
    flat = x.reshape(-1)
    pad = (-flat.size) % Q_BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, Q_BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(fp / safe), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    fp = q.astype(jnp.float32) * scale
    n = 1
    for d in shape:
        n *= d
    return fp.reshape(-1)[:n].reshape(shape)


def _q8_sqrt(v: jnp.ndarray):
    """Non-negative second moment -> int8 in sqrt domain (range compression:
    the linear absmax code would flush small-v entries in a block to zero and
    the Adam denominator would explode - the bitsandbytes dynamic-quant
    problem, solved here with sqrt coding + a half-step floor)."""
    return _q8(jnp.sqrt(v))


def _dq8_sqrt(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    s = q.astype(jnp.float32) * scale
    floor = scale / (2.0 * 127.0)                  # half quantization step
    s = jnp.maximum(s, jnp.broadcast_to(floor, s.shape))
    n = 1
    for d in shape:
        n *= d
    return (s * s).reshape(-1)[:n].reshape(shape)


class _Moment(NamedTuple):
    q: jnp.ndarray
    scale: jnp.ndarray


def _zeros_moment(p: jnp.ndarray, eight_bit: bool):
    if not eight_bit:
        return jnp.zeros(p.shape, jnp.float32)
    n = p.size
    blocks = -(-n // Q_BLOCK)
    return _Moment(jnp.zeros((blocks, Q_BLOCK), jnp.int8),
                   jnp.zeros((blocks, 1), jnp.float32))


def init(params, cfg: AdamWConfig):
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _zeros_moment(p, cfg.eight_bit), params),
        "v": jax.tree.map(lambda p: _zeros_moment(p, cfg.eight_bit), params),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig,
           lr: Optional[jnp.ndarray] = None):
    """One AdamW step. Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step) if lr is None else lr
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        mf = _dq8(m.q, m.scale, p.shape) if isinstance(m, _Moment) else m
        vf = _dq8_sqrt(v.q, v.scale, p.shape) if isinstance(v, _Moment) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) * (1 - lr * cfg.weight_decay) - lr * upd
        if isinstance(m, _Moment):
            mq, ms = _q8(mf)
            vq, vs = _q8_sqrt(vf)
            return newp.astype(p.dtype), _Moment(mq, ms), _Moment(vq, vs)
        return newp.astype(p.dtype), mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [leaf(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "m": new_m, "v": new_v}, stats
