from repro.train import losses, optimizer, train_state
from repro.train.optimizer import AdamWConfig
