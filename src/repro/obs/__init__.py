"""repro.obs - context-scoped tracing, counters, roofline-annotated spans.

The runtime-observability layer the paper's accounting argument needs at
execution time: *which* kernel config dispatch resolved (and from where),
*how many* bytes a SUMMA ring hop moved, *what fraction* of the modeled
machine peak a routine achieved. Three pieces::

    from repro import linalg, obs

    with obs.trace(name="qr") as tr:        # contextvar-scoped capture
        with linalg.use(policy="tuned"):
            linalg.qr(a)                    # spans + provenance events

    print(obs.summary(tr))                  # per-op rollup + counters
    obs.save_chrome_trace(tr, "qr.trace.json")   # chrome://tracing file

* :func:`trace` / :func:`span` / :func:`event` / :func:`annotate` - the
  tracer (:mod:`repro.obs.trace`). Zero-cost no-op when no trace is
  active; instrumented layers (linalg routines, ``tune.dispatch``,
  ``distributed.collectives``, ``tune.measure``, ``launch.serve``) emit
  spans/events only under an active capture.
* :mod:`repro.obs.counters` - always-on monotonic process counters
  (dispatch/registry/kernel/collective accounting); each trace reports
  the delta it covered.
* :mod:`repro.obs.export` - Chrome ``trace_event``, JSON-lines, and
  plain-text summary exporters (CLI: ``scripts/trace_report.py``).

Capture scoping composes with :func:`repro.linalg.use` through the
context's ``obs`` field: ``UNSET``/``None`` inherit the ambient trace,
``obs=False`` suppresses capture inside the scope, and ``obs=tr`` routes
spans into an explicit :class:`Trace`. See ``docs/observability.md``.
"""
from repro.obs.counters import (KNOWN_COUNTERS, delta as counters_delta,
                                inc, reset as reset_counters,
                                snapshot as counters_snapshot, value as
                                counter)
from repro.obs.export import (save_chrome_trace, save_jsonl, summary,
                              to_chrome_trace, to_jsonl)
from repro.obs.trace import (EVENT_FIELDS, NOOP_SPAN, SCHEMA_VERSION, Span,
                             Trace, annotate, capture, current_trace,
                             enabled, event, span, trace)

__all__ = [
    # schema
    "SCHEMA_VERSION", "EVENT_FIELDS",
    # tracer
    "Trace", "Span", "trace", "capture", "span", "event", "annotate",
    "enabled", "current_trace", "NOOP_SPAN",
    # counters
    "KNOWN_COUNTERS", "inc", "counter", "counters_snapshot",
    "counters_delta", "reset_counters",
    # exporters
    "to_chrome_trace", "save_chrome_trace", "to_jsonl", "save_jsonl",
    "summary",
]
