"""Trace exporters: Chrome trace_event JSON, JSON-lines, text summary.

Three serializations of one :class:`repro.obs.Trace`:

``to_chrome_trace`` / ``save_chrome_trace``
    The Chrome/Perfetto ``trace_event`` object format: closed spans
    become ``"ph": "X"`` complete events (``ts``/``dur`` in microseconds,
    sorted by ``ts``), instant events ``"ph": "i"``; span attrs ride in
    ``args`` and the counter delta + schema version in ``otherData``.
    Load the file in ``chrome://tracing`` or https://ui.perfetto.dev.

``to_jsonl`` / ``save_jsonl``
    One JSON object per line: a ``{"kind": "header"}`` line (schema
    version, trace name), one ``{"kind": "event"}`` line per event in the
    frozen :data:`repro.obs.EVENT_FIELDS` layout, and a final
    ``{"kind": "counters"}`` line - the grep/pandas-friendly form.

``summary``
    Plain-text per-(cat, name) aggregation; ``scripts/trace_report.py``
    prints it for either on-disk format.
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.obs.trace import SCHEMA_VERSION, Span, Trace


def _sorted_events(tr: Trace) -> List[Span]:
    # spans append at close (children first); exporters order by start
    # time so consumers (and the monotonic-ts validator) see begin order
    return sorted(tr.events, key=lambda e: (e.t_start or 0.0, e.id or 0))


def to_chrome_trace(tr: Trace) -> Dict:
    """Trace -> Chrome ``trace_event`` object (JSON-able dict)."""
    events = []
    for e in _sorted_events(tr):
        d = e.to_dict()
        rec = {"name": e.name, "cat": e.cat, "pid": 0, "tid": 0,
               "ts": round((e.t_start or 0.0) * 1e6, 3),
               "args": dict(d["attrs"], id=e.id, parent=e.parent)}
        if e.t_end is None:
            rec.update(ph="i", s="t")               # thread-scoped instant
        else:
            rec.update(ph="X", dur=round((e.t_end - e.t_start) * 1e6, 3))
        events.append(rec)
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"schema_version": SCHEMA_VERSION,
                          "trace_name": tr.name,
                          "counters": dict(tr.counters)}}


def save_chrome_trace(tr: Trace, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tr), f, indent=1)
    return path


def to_jsonl(tr: Trace) -> str:
    """Trace -> JSON-lines text (header, events, counters)."""
    lines = [json.dumps({"kind": "header", "schema_version": SCHEMA_VERSION,
                         "trace_name": tr.name})]
    lines += [json.dumps(dict(e.to_dict(), kind="event"))
              for e in _sorted_events(tr)]
    lines.append(json.dumps({"kind": "counters",
                             "counters": dict(tr.counters)}))
    return "\n".join(lines) + "\n"


def save_jsonl(tr: Trace, path: str) -> str:
    with open(path, "w") as f:
        f.write(to_jsonl(tr))
    return path


def summary(tr: Trace) -> str:
    """Plain-text rollup: per-(cat, name) count/total/mean wall time, the
    mean fraction-of-modeled-peak where spans priced one, and the counter
    delta."""
    groups: Dict = {}
    for e in tr.events:
        key = (e.cat, e.name)
        g = groups.setdefault(key, {"count": 0, "total_s": 0.0,
                                    "fracs": []})
        g["count"] += 1
        if e.t_end is not None and e.t_start is not None:
            g["total_s"] += e.t_end - e.t_start
        frac = e.attrs.get("fraction_of_modeled_peak")
        if isinstance(frac, (int, float)):
            g["fracs"].append(float(frac))
    lines = [f"trace {tr.name!r}: {len(tr.events)} events "
             f"(schema v{SCHEMA_VERSION})",
             f"{'cat':<12} {'name':<28} {'count':>6} {'total_ms':>10} "
             f"{'mean_ms':>9} {'frac_peak':>10}"]
    for (cat, name), g in sorted(groups.items(),
                                 key=lambda kv: -kv[1]["total_s"]):
        mean_ms = 1e3 * g["total_s"] / g["count"] if g["count"] else 0.0
        frac = (sum(g["fracs"]) / len(g["fracs"])) if g["fracs"] else None
        frac_s = f"{frac:.2e}" if frac is not None else "-"
        lines.append(f"{cat:<12} {name:<28} {g['count']:>6} "
                     f"{1e3 * g['total_s']:>10.3f} {mean_ms:>9.3f} "
                     f"{frac_s:>10}")
    if tr.counters:
        lines.append("counters:")
        lines += [f"  {k:<28} {v}" for k, v in sorted(tr.counters.items())]
    return "\n".join(lines)
