"""Process-global monotonic counters: always-on runtime accounting.

Counters are the cheap half of :mod:`repro.obs`: unconditional integer
increments (one dict ``+=`` per occurrence, no contextvar lookup), so the
layers that matter can account every occurrence - even when no trace is
active. A :class:`repro.obs.Trace` snapshots the counter table at start
and again at finish, so each trace reports the *delta* it covered.

The names below are the frozen vocabulary the rest of the repo
increments (``scripts/check_api_surface.py`` guards it; add new names
there in the same PR):

``dispatch.resolve``
    One per :func:`repro.tune.dispatch.resolve` call - every kernel-shaped
    BLAS/LAPACK core resolves exactly once per (traced) call.
``dispatch.registry_hit`` / ``dispatch.registry_miss``
    Tuned-policy resolutions that found / missed a registry config
    (miss == ``source="fallback-model"``).
``registry.load``
    :meth:`repro.tune.registry.Registry.load` invocations.
``registry.missing_fallback``
    Loads that found no file (cold start - normal, not warned).
``registry.corrupt_fallback``
    Loads that found an unreadable/schema-incompatible file (warned once
    per path via ``warnings.warn``).
``kernel.launch``
    Pallas kernel launches funneled through the dispatch GEMM executor.
``collective.hops`` / ``collective.bytes``
    Ring-broadcast ppermute hops and on-wire bytes (counted at trace
    time: a jit-cached SUMMA call re-runs the collective without
    re-tracing, so these count *distinct traced schedules*, not
    executions).
"""
from __future__ import annotations

from typing import Dict

# the frozen counter vocabulary (see module docstring); incrementing an
# unlisted name is allowed (prototyping) but the API-surface guard keeps
# this tuple in sync with what shipping code uses
KNOWN_COUNTERS = (
    "dispatch.resolve",
    "dispatch.registry_hit",
    "dispatch.registry_miss",
    "registry.load",
    "registry.missing_fallback",
    "registry.corrupt_fallback",
    "kernel.launch",
    "collective.hops",
    "collective.bytes",
)

_counts: Dict[str, int] = {}


def inc(name: str, n: int = 1) -> int:
    """Add ``n`` to counter ``name`` (created at 0); returns the new value."""
    v = _counts.get(name, 0) + int(n)
    _counts[name] = v
    return v


def value(name: str) -> int:
    """Current value of ``name`` (0 if never incremented)."""
    return _counts.get(name, 0)


def snapshot() -> Dict[str, int]:
    """Copy of the whole counter table (monotonic; never reset by traces)."""
    return dict(_counts)


def delta(before: Dict[str, int]) -> Dict[str, int]:
    """Counters that moved since ``before`` (a :func:`snapshot`), as
    name -> increment. Names absent from ``before`` count from 0."""
    return {k: v - before.get(k, 0) for k, v in _counts.items()
            if v != before.get(k, 0)}


def reset() -> None:
    """Zero every counter (tests only - counters are process-monotonic;
    shipping code should diff :func:`snapshot`\\ s instead)."""
    _counts.clear()
