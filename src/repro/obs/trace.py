"""Contextvar-scoped tracer: spans, instant events, roofline annotation.

The capture scope mirrors how :func:`repro.linalg.use` and
:func:`repro.arch.machine_scope` already work: a
:class:`contextvars.ContextVar` holds the active :class:`Trace` (or
``None``), so concurrent threads and asyncio tasks each see only their
own capture. The cardinal rule is that observation never changes
numerics or, when disabled, costs anything measurable:

* **Disabled path**: :func:`span` checks one contextvar and returns a
  shared no-op singleton - no ``Span`` object, no attrs dict retained, no
  timestamps taken. The :mod:`repro.linalg` routine wrappers go further
  and skip the :func:`span` call entirely (a dict-free early return into
  the numeric body), so an untraced call is byte-for-byte the pre-obs
  code path.
* **Enabled path**: a :class:`Span` records wall time
  (``time.perf_counter`` relative to the trace epoch), name/category,
  whatever the instrumentation :meth:`Span.annotate`\\ s (shapes, dtype,
  resolved config + provenance, flop/byte counts), and - when ``flops``
  was annotated - derived roofline metrics priced by the ambient
  :class:`repro.arch.MachineSpec` at close: ``achieved_gflops``,
  ``fraction_of_modeled_peak`` (achieved / ``pe.peak_flops``),
  ``modeled_s`` (max of the compute and ``memory.hbm_bw`` roofline legs)
  and ``model_residual`` (same definition as
  :func:`repro.tune.measure.model_residual`).

JIT caveat (document once, everywhere): spans wrap *Python* execution.
Inside ``jax.jit`` they capture trace-time structure - which configs
resolved, which collectives were scheduled - and their wall time includes
compilation on the first call; they do not time per-execution device work
(that is :func:`repro.tune.measure.measure`'s job, which annotates its
rep statistics onto the enclosing span).

``repro.arch`` is imported lazily inside the finalizer: the import chain
``arch -> arch.calibrate -> tune.measure -> obs`` would otherwise cycle.
"""
from __future__ import annotations

import contextlib
import contextvars
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.obs import counters as _counters

#: bump when the serialized event layout changes; exporters embed it and
#: ``scripts/trace_report.py --validate`` rejects mismatches
SCHEMA_VERSION = 1

#: the frozen per-event field set every exporter writes
#: (``scripts/check_api_surface.py`` guards it)
EVENT_FIELDS = ("name", "cat", "id", "parent", "t_start", "t_end", "attrs")

_current: "contextvars.ContextVar[Optional[Trace]]" = \
    contextvars.ContextVar("repro_obs_trace", default=None)
_stack: "contextvars.ContextVar[Tuple[Span, ...]]" = \
    contextvars.ContextVar("repro_obs_spans", default=())


def _jsonable(v):
    """Best-effort conversion of annotation values to JSON-able types."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    # numpy / jnp scalars expose item(); anything else falls back to repr
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return repr(v)


class Trace:
    """One capture: an ordered event list plus the counter delta it saw.

    Created by :func:`trace` (or explicitly and routed through
    ``linalg.use(obs=tr)`` / :func:`capture`). Events are appended as
    spans *close* (children before parents); exporters sort by start
    time. ``counters`` holds the process-counter delta between start and
    :meth:`finish`.
    """

    def __init__(self, name: str = "trace"):
        self.name = str(name)
        self.t0 = time.perf_counter()
        self.events: List["Span"] = []
        self.counters: Dict[str, int] = {}
        self.finished = False
        self._next_id = 0
        self._counters0 = _counters.snapshot()

    def next_id(self) -> int:
        i = self._next_id
        self._next_id = i + 1
        return i

    def finish(self) -> "Trace":
        """Freeze the counter delta (idempotent); called by :func:`trace`
        on scope exit."""
        if not self.finished:
            self.finished = True
            self.counters = _counters.delta(self._counters0)
        return self

    def spans(self, name: Optional[str] = None,
              cat: Optional[str] = None) -> List["Span"]:
        """Events filtered by exact name and/or category."""
        return [e for e in self.events
                if (name is None or e.name == name)
                and (cat is None or e.cat == cat)]

    def __repr__(self) -> str:
        return (f"Trace({self.name!r}, events={len(self.events)}, "
                f"finished={self.finished})")


class _NoopSpan:
    """Shared do-nothing span: the disabled-path return value."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def annotate(self, **attrs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed region (or, with ``t_end=None``, one instant event).

    Use as a context manager (via :func:`span`); :meth:`annotate` merges
    attribute dicts at any point before close. Closing computes the
    derived roofline attrs when ``flops`` is present (see module
    docstring) and appends the span to its trace.
    """

    __slots__ = ("trace", "name", "cat", "id", "parent", "t_start", "t_end",
                 "attrs", "_token")

    def __init__(self, trace: Trace, name: str, cat: str,
                 attrs: Optional[Dict[str, Any]] = None):
        self.trace = trace
        self.name = str(name)
        self.cat = str(cat)
        self.id: Optional[int] = None
        self.parent: Optional[int] = None
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self._token = None

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        open_spans = _stack.get()
        self.id = self.trace.next_id()
        # parent only within the same trace (capture() can switch traces
        # mid-stack; ids from another trace would dangle)
        self.parent = open_spans[-1].id if open_spans and \
            open_spans[-1].trace is self.trace else None
        self._token = _stack.set(open_spans + (self,))
        self.t_start = time.perf_counter() - self.trace.t0
        return self

    def __exit__(self, *exc) -> bool:
        self.t_end = time.perf_counter() - self.trace.t0
        if self._token is not None:
            _stack.reset(self._token)
            self._token = None
        self._finalize()
        self.trace.events.append(self)
        return False

    # ------------------------- roofline pricing -----------------------------

    def _finalize(self) -> None:
        at = self.attrs
        flops = at.get("flops")
        if flops is None or self.t_end is None or self.t_start is None:
            return
        try:
            from repro import arch                  # lazy: avoid import cycle
            mach = arch.current_machine()
        except Exception:                           # pragma: no cover
            return
        at.setdefault("machine", mach.name)
        wall = self.t_end - self.t_start
        peak = mach.pe.peak_flops
        nbytes = at.get("bytes")
        modeled = flops / peak if peak > 0 else float("nan")
        if nbytes and mach.memory.hbm_bw > 0:
            modeled = max(modeled, nbytes / mach.memory.hbm_bw)
        at["modeled_s"] = modeled
        if wall > 0:
            at["wall_s"] = wall
            at["achieved_gflops"] = flops / wall / 1e9
            if peak > 0:
                at["fraction_of_modeled_peak"] = (flops / wall) / peak
            # same definition as repro.tune.measure.model_residual
            at["model_residual"] = (wall - modeled) / wall

    def to_dict(self) -> Dict[str, Any]:
        """The frozen :data:`EVENT_FIELDS` record (JSON-able)."""
        return {"name": self.name, "cat": self.cat, "id": self.id,
                "parent": self.parent, "t_start": self.t_start,
                "t_end": self.t_end, "attrs": _jsonable(self.attrs)}

    def __repr__(self) -> str:
        dur = (None if self.t_end is None or self.t_start is None
               else self.t_end - self.t_start)
        return f"Span({self.name!r}, cat={self.cat!r}, dur={dur})"


# ------------------------------ capture scope -------------------------------

def enabled() -> bool:
    """True when a trace is capturing in this context (one var lookup)."""
    return _current.get() is not None


def current_trace() -> Optional[Trace]:
    """The capturing :class:`Trace`, or ``None``."""
    return _current.get()


@contextlib.contextmanager
def trace(name: str = "trace") -> Iterator[Trace]:
    """Capture everything in the dynamic extent into a fresh trace::

        with obs.trace(name="qr-sweep") as tr:
            linalg.qr(a)
        obs.save_chrome_trace(tr, "qr.trace.json")
    """
    tr = Trace(name)
    token = _current.set(tr)
    try:
        yield tr
    finally:
        _current.reset(token)
        tr.finish()


@contextlib.contextmanager
def capture(tr: Optional[Trace]) -> Iterator[Optional[Trace]]:
    """Route capture into an existing trace (``None`` suppresses capture -
    how ``linalg.use(obs=False)`` masks an ambient trace)."""
    token = _current.set(tr)
    try:
        yield tr
    finally:
        _current.reset(token)


def span(name: str, cat: str = "custom", **attrs):
    """Open a span under the active trace; a shared no-op when disabled.

    ``with obs.span("linalg.gemm", cat="routine", flops=2*m*n*k): ...``
    """
    tr = _current.get()
    if tr is None:
        return NOOP_SPAN
    return Span(tr, name, cat, attrs)


def event(name: str, cat: str = "instant", **attrs) -> Optional[Span]:
    """Record an instant event (``t_end=None``) under the open span."""
    tr = _current.get()
    if tr is None:
        return None
    ev = Span(tr, name, cat, attrs)
    ev.id = tr.next_id()
    open_spans = _stack.get()
    ev.parent = open_spans[-1].id if open_spans and \
        open_spans[-1].trace is tr else None
    ev.t_start = time.perf_counter() - tr.t0
    tr.events.append(ev)
    return ev


def annotate(**attrs) -> bool:
    """Merge ``attrs`` onto the innermost open span; False if none is
    open (or tracing is disabled) - never raises."""
    open_spans = _stack.get()
    if not open_spans:
        return False
    open_spans[-1].annotate(**attrs)
    return True
