from repro.runtime.fault_tolerance import (Heartbeat, SimulatedFailure,
                                           StragglerDetector,
                                           run_with_restarts)
