"""Fault tolerance: restart policy, heartbeat, straggler detection.

Designed for the 1000+-node regime and exercised here single-host:

  * ``run_with_restarts`` - supervises a training loop; on failure it
    restores the latest atomic checkpoint and resumes (bounded restarts,
    exponential backoff). Node loss on a real cluster surfaces as exactly
    this: the job restarts from the last checkpoint on the surviving+replaced
    nodes (elastic_restore covers a changed mesh).
  * ``Heartbeat`` - per-step liveness file; an external supervisor (or the
    included ``watchdog``) detects a wedged job by heartbeat age.
  * ``StragglerDetector`` - per-step wall-time EWMA + deviation; steps slower
    than ``threshold`` x the running median are flagged with their step index
    (on a cluster: rank). Persistent stragglers trigger a report so the
    scheduler can evict the slow host - mitigation is *detection + restart
    without the bad node*, the standard large-fleet pattern.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, List, Optional

import numpy as np


class SimulatedFailure(RuntimeError):
    """Raised by tests/examples to emulate a node loss."""


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed: bool
    resume_steps: List[int]


def run_with_restarts(make_loop: Callable[[Optional[int]], int],
                      max_restarts: int = 3,
                      backoff_s: float = 0.0) -> RestartReport:
    """``make_loop(resume_step)`` runs training until done (returns final
    step) or raises. On exception we restart from the latest checkpoint
    (the loop itself restores state via its CheckpointManager)."""
    restarts = 0
    resume_steps: List[int] = []
    while True:
        try:
            make_loop(None if not resume_steps else resume_steps[-1])
            return RestartReport(restarts, True, resume_steps)
        except (SimulatedFailure, RuntimeError) as e:  # noqa: PERF203
            restarts += 1
            if restarts > max_restarts:
                return RestartReport(restarts - 1, False, resume_steps)
            resume_steps.append(getattr(e, "step", -1))
            if backoff_s:
                time.sleep(backoff_s * (2 ** (restarts - 1)))


class Heartbeat:
    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def is_stale(self, timeout_s: float) -> bool:
        age = self.age()
        return age is None or age > timeout_s


class StragglerDetector:
    """Flags steps (ranks, on a cluster) whose duration exceeds
    ``threshold`` x running median over a sliding window."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.durations: List[float] = []
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, duration_s: float) -> bool:
        hist = self.durations[-self.window:]
        self.durations.append(duration_s)
        if len(hist) >= 5:
            med = float(np.median(hist))
            if duration_s > self.threshold * med:
                self.flagged.append(step)
                return True
        return False

    def report(self) -> dict:
        d = np.asarray(self.durations) if self.durations else np.zeros(1)
        return {"steps": len(self.durations),
                "median_s": float(np.median(d)),
                "p95_s": float(np.percentile(d, 95)),
                "flagged": list(self.flagged)}
